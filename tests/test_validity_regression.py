"""Conformal validity regression, end-to-end THROUGH the serving engine.

The LTT guarantee (P(risk <= delta) >= 1 - eps) covers the deployed
procedure.  These tests calibrate offline, then deploy lambda* through the
real continuous-batching stack (``OrcaScheduler`` + fused Pallas probe step)
over a synthetic trajectory distribution with KNOWN injected label noise,
and assert (a) the served stop decisions equal the calibrated offline
procedure's exactly and (b) the served empirical risk respects delta (plus
an explicit finite-sample slack) — for BOTH the TTT probe and the static
baseline flattened into kernel state.  Seeded and deterministic.
"""
import numpy as np
import pytest

import jax

from repro import api as orca
from repro.core import stopping as S
from repro.core.calibrator import GroupCalibrator, groups_from_trajectories
from repro.core.pipeline import make_labels
from repro.core.probe import ProbeConfig
from repro.serving import (OrcaScheduler, ServeConfig, make_group_fleet,
                           replay_model, replay_params, replay_requests,
                           served_stop_times)
from repro.trajectories.synthetic import TrajectoryDistribution, generate

DELTA, EPS = 0.25, 0.1
SLACK = 0.1                 # finite-sample fluctuation of the test risk
NOISE = 0.12                # known label-noise rate (false breakthroughs)
D_PHI = 48


@pytest.fixture(scope="module")
def noisy_splits():
    dist = TrajectoryDistribution("validity", d_phi=D_PHI, t_min=30, t_max=60)
    full = generate(dist, 360, seed=11)
    # known label noise: flip a fraction of solved trajectories to "never
    # correct" — stopping on their (still-present) feature breakthrough is
    # guaranteed to be charged as risk.  Applied iid BEFORE the split, so
    # calibration and test remain exchangeable and LTT stays valid.
    rs = np.random.RandomState(99)
    flip = rs.rand(len(full)) < NOISE
    full.correct[flip] = False
    idx = rs.permutation(len(full))
    return (full.subset(idx[:160]), full.subset(idx[160:260]),
            full.subset(idx[260:]))


def _serve(calibrator, test, lam, chunk_tokens=None, policy=None,
           pack_chunks=False, priorities=None):
    pc, theta = calibrator.serving_params()
    cfg = ServeConfig(tokens_per_step=1,
                      max_new_tokens=int(test.lengths.max()),
                      lam=float(lam), burn_in=10)
    # served through the PAGED scheduler with a pool deliberately smaller
    # than slots x blocks-per-request: admission reserves pages and
    # backpressures (requests WAIT) — validity must survive the paged
    # capacity mechanism, not just the slot mechanism
    max_blocks = (int(test.lengths.max()) + 1 + 15) // 16
    sched = OrcaScheduler(replay_model(test.phis), replay_params(test.phis),
                          pc, theta, cfg, n_slots=4, paged=True,
                          block_size=16, num_blocks=1 + 3 * max_blocks,
                          chunk_tokens=chunk_tokens, policy=policy,
                          pack_chunks=pack_chunks)
    reqs = replay_requests(test.lengths)
    for i, r in enumerate(reqs):
        # two classes by default: exercises priority policies
        r.priority = priorities[i] if priorities is not None else i % 2
    done, fleet = sched.run(reqs)
    assert fleet.peak_blocks_in_use <= 3 * max_blocks
    return served_stop_times(done, test.lengths), fleet


def _assert_served_validity(calibrator, cal, test):
    lam = calibrator.calibrate(cal, DELTA, EPS)
    assert np.isfinite(lam), "LTT selected nothing — fixture mistuned"
    tau_srv, fleet = _serve(calibrator, test, lam)
    # the served procedure IS the calibrated procedure: stop-for-stop equal
    tau_off = S.stop_times(calibrator.scores(test), [lam], test.mask)[:, 0]
    np.testing.assert_array_equal(tau_srv, tau_off)
    # chunked prefill (prompt scheduled through the unified token-budget
    # step, mid-prefill admissions riding live decode) must not move a
    # single stop — served through a PACKED PRIORITY scheduler (multi-
    # request chunks + class-reordered admission): same offline equality,
    # bit for bit, because scheduling moves WHEN work happens, never what
    # the probe sees
    tau_chunk, fleet_chunk = _serve(calibrator, test, lam, chunk_tokens=3,
                                    policy="priority", pack_chunks=True)
    np.testing.assert_array_equal(tau_chunk, tau_off)
    assert fleet_chunk.packed_chunks > 0, "packing never engaged"
    # involuntary preemption (an overload burst: urgent class-0 requests
    # hit a full fleet and spill lower-class residents' KV AND probe state
    # to host RAM, restored later) must not move a single stop either —
    # the spill/restore round trip is byte-exact, so the conformal
    # guarantee is preemption-schedule invariant
    prio = [1, 1, 1, 0, 0] + [2] * (len(test) - 5)
    tau_pre, fleet_pre = _serve(calibrator, test, lam, priorities=prio)
    assert fleet_pre.preemptions > 0, "overload never forced a spill"
    assert fleet_pre.restores == fleet_pre.preemptions
    np.testing.assert_array_equal(tau_pre, tau_off)
    # and it respects the calibrated risk level on held-out data
    labels = make_labels(test, calibrator.mode)
    risk = float(S.procedure_risk(tau_srv[:, None], labels, test.mask).mean())
    assert risk <= DELTA + SLACK, f"served risk {risk:.3f} > {DELTA}+{SLACK}"
    # non-vacuous: the threshold actually stops sequences early
    sav = float(S.savings(tau_srv[:, None], test.mask)[0])
    assert sav > 0.05, f"procedure never stopped early (savings {sav:.3f})"
    assert fleet.engine_steps > 0 and fleet.n_requests == len(test)
    return risk, sav


def _assert_group_validity(calibrator, cal, test, group_size=3):
    """Group-level conformal validity, served end-to-end: the consensus
    threshold is LTT-calibrated over calibration GROUPS (same per-sample
    answer-hash convention ``make_group_fleet`` serves), deployed through
    the gang-scheduling consensus scheduler, and the served group risk
    (consensus fired AND voted wrong) must respect delta + slack."""
    lam = calibrator.calibrate(cal, DELTA, EPS)
    assert np.isfinite(lam)
    # calibration groups: same seeded permutation + chunking as the fleet,
    # with each sample's per-step vote broadcast from its fleet answer hash
    cal_fleet = make_group_fleet(cal, group_size, seed=21)
    a_cal = np.repeat(cal_fleet.answer_hash[:, None], cal.phis.shape[1],
                      axis=1)
    traces = groups_from_trajectories(cal, calibrator.scores(cal),
                                      group_size, seed=21, answers=a_cal)
    assert [int(t.truth) for t in traces] == cal_fleet.truth.tolist()
    gc = GroupCalibrator(min_votes=2, burn_in=10)
    g_lam = gc.calibrate(traces, DELTA, EPS, per_sample_lam=lam,
                         per_sample_burn_in=10)
    assert np.isfinite(g_lam), "group LTT selected nothing"

    fleet_ts = make_group_fleet(test, group_size, seed=22)
    pc, theta = calibrator.serving_params()
    cfg = ServeConfig(tokens_per_step=1,
                      max_new_tokens=int(test.lengths.max()),
                      lam=float(lam), burn_in=10)
    max_blocks = (int(test.lengths.max()) + 1 + 15) // 16
    sched = OrcaScheduler(fleet_ts.model, fleet_ts.params, pc, theta, cfg,
                          n_slots=4, paged=True, block_size=16,
                          num_blocks=1 + (group_size + 1) * max_blocks,
                          consensus=gc)
    done, fleet = sched.run(fleet_ts.requests)
    assert all(r.done for r in done)
    assert sched.pool.num_free == sched.pool.num_usable
    # served group risk: a fired consensus is charged iff its answer is
    # wrong; a never-firing group is never charged (same loss LTT bounded)
    risks = [float(g.decided and g.consensus_answer
                   != int(fleet_ts.truth[g.group_id]))
             for g in sched.groups]
    risk = float(np.mean(risks))
    assert risk <= DELTA + SLACK, \
        f"served group risk {risk:.3f} > {DELTA}+{SLACK}"
    # non-vacuous: the consensus actually fires and cancels siblings
    assert fleet.consensus_groups > 0, "consensus never fired"
    assert fleet.samples_cancelled > 0 and fleet.group_savings > 0.0
    return risk


def test_ttt_calibrator_validity_through_engine(noisy_splits):
    train, cal, test = noisy_splits
    calib = orca.fit(train, mode="supervised", method="ttt",
                     pc=ProbeConfig(d_phi=D_PHI, smooth_window=5),
                     epochs=6, batch_size=32, epoch_select=False, seed=0)
    risk, sav = _assert_served_validity(calib, cal, test)
    # with 12% of breakthroughs poisoned the observed risk must be real
    # (the threshold can't dodge noise it can't see) yet still controlled
    assert risk > 0.0


def test_static_calibrator_validity_through_engine(noisy_splits):
    """The static baseline rides the SAME fused engine: serving_params
    flattens PCA+logreg into frozen (eta=0) kernel state."""
    train, cal, test = noisy_splits
    calib = orca.fit(train, mode="supervised", method="static",
                     n_components=16, smooth_window=5, epochs=150)
    _assert_served_validity(calib, cal, test)


def test_ttt_group_consensus_validity_through_engine(noisy_splits):
    train, cal, test = noisy_splits
    calib = orca.fit(train, mode="supervised", method="ttt",
                     pc=ProbeConfig(d_phi=D_PHI, smooth_window=5),
                     epochs=6, batch_size=32, epoch_select=False, seed=0)
    _assert_group_validity(calib, cal, test)


def test_static_group_consensus_validity_through_engine(noisy_splits):
    train, cal, test = noisy_splits
    calib = orca.fit(train, mode="supervised", method="static",
                     n_components=16, smooth_window=5, epochs=150)
    _assert_group_validity(calib, cal, test)


def test_static_serving_params_round_trip(noisy_splits):
    """Offline static scores == the frozen linear probe the engine deploys."""
    train, _, test = noisy_splits
    calib = orca.fit(train, mode="supervised", method="static",
                     n_components=16, smooth_window=5, epochs=150)
    pc, theta = calib.serving_params()
    assert pc.eta == 0.0 and pc.variant == "noqk"
    assert theta["W0"].shape == (D_PHI,)
    w = np.asarray(theta["W0"], np.float64)
    b = float(theta["b0"])
    raw = 1.0 / (1.0 + np.exp(-(test.phis.astype(np.float64) @ w + b)))
    from repro.core.probe import smooth_scores
    import jax.numpy as jnp
    smoothed = np.asarray(smooth_scores(jnp.asarray(raw), pc.smooth_window))
    np.testing.assert_allclose(smoothed * test.mask, calib.scores(test),
                               atol=2e-5)
