"""Numerical consistency across execution paths (the bugs these catch:
rope/position errors, cache indexing, ring-slot arithmetic, token-shift and
SSM state carry, blockwise-softmax accumulation).

1. prefill(prompt) + decode_step*(k) logits == teacher-forced forward logits
   at the same positions, per architecture family.
2. blockwise flash attention == einsum attention at the model level.
3. int8 KV cache decode stays close to the bf16/f32 cache decode.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import InputShape, get_config
from repro.models import build
from repro.models.attention import (attn_prefill_blockwise,
                                    attn_prefill_einsum)

PROMPT, GEN = 12, 6


def _greedy_reference(model, params, tokens_full, batch_extra):
    """Teacher-forced forward over the full sequence -> logits (B,S,V)."""
    cfg = model.cfg
    batch = {"tokens": tokens_full, **batch_extra}
    logits, hidden, _ = model.forward(cfg, params, batch)
    return np.asarray(logits, np.float32)


@pytest.mark.parametrize("arch", ["smollm_360m", "qwen15_32b", "rwkv6_1b6",
                                  "hymba_1b5", "granite_moe_1b"])
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B = 2
    rng = jax.random.PRNGKey(1)
    tokens = jax.random.randint(rng, (B, PROMPT + GEN), 0, cfg.vocab_size)
    extra = {}
    ref = _greedy_reference(model, params, tokens, extra)

    cache_len = cfg.n_meta_tokens + PROMPT + GEN + 2
    state, last_h, _ = model.prefill(cfg, params,
                                     {"tokens": tokens[:, :PROMPT], **extra},
                                     cache_len)
    # decode the remaining tokens teacher-forced, compare logits
    prefix = cfg.n_meta_tokens  # meta tokens shift absolute positions
    _, window = model.decode_geometry(InputShape("d", cache_len, B, "decode"))
    for i in range(GEN):
        pos = jnp.asarray(prefix + PROMPT + i, jnp.int32)
        tok = tokens[:, PROMPT + i]
        logits, hidden, state = model.decode_step(cfg, params, tok, state, pos,
                                                  window=window)
        got = np.asarray(logits, np.float32)
        # forward() prepends the meta tokens, so the teacher-forced logits
        # for token PROMPT+i sit at sequence index prefix + PROMPT + i
        want = ref[:, prefix + PROMPT + i, :]
        np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2,
                                   err_msg=f"{arch} step {i}")


def test_whisper_prefill_decode_matches_forward():
    cfg = get_config("whisper_tiny").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 8
    rng = jax.random.PRNGKey(1)
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    frames = jax.random.normal(rng, (B, cfg.frontend.n_tokens, cfg.d_model)) * 0.02
    ref = _greedy_reference(model, params, tokens, {"frames": frames})
    state, _, _ = model.prefill(cfg, params, {"frames": frames}, S + 2)
    for i in range(S):
        pos = jnp.asarray(i, jnp.int32)
        logits, _, state = model.decode_step(cfg, params, tokens[:, i], state,
                                             pos)
        np.testing.assert_allclose(np.asarray(logits, np.float32),
                                   ref[:, i, :], rtol=2e-2, atol=2e-2,
                                   err_msg=f"whisper step {i}")


@pytest.mark.parametrize("causal,window", [(True, None), (True, 24)])
def test_blockwise_matches_einsum_model_level(causal, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    b, s, h, kv, d = 2, 64, 4, 2, 32
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    ref = attn_prefill_einsum(q, k, v, causal=causal, window=window)
    for diff in (False, True):
        out = attn_prefill_blockwise(q, k, v, causal=causal, window=window,
                                     q_block=16, kv_block=16,
                                     differentiable=diff)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


def test_int8_cache_close_to_fp_cache():
    cfg = get_config("smollm_360m").reduced()
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    model, model8 = build(cfg), build(cfg8)
    params = model.init(jax.random.PRNGKey(0))
    B = 2
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, PROMPT), 0,
                                cfg.vocab_size)
    outs = []
    for m, c in ((model, cfg), (model8, cfg8)):
        state, _, _ = m.prefill(c, params, {"tokens": tokens}, PROMPT + 4)
        tok = jnp.zeros((B,), jnp.int32)
        for i in range(3):
            logits, _, state = m.decode_step(c, params, tok, state,
                                             jnp.asarray(PROMPT + i, jnp.int32))
            tok = jnp.argmax(logits[:, :c.vocab_size], -1).astype(jnp.int32)
        outs.append(np.asarray(logits, np.float32))
    # int8 quantization error should stay small relative to logit scale
    scale = np.abs(outs[0]).mean()
    err = np.abs(outs[0] - outs[1]).mean()
    assert err < 0.15 * scale, (err, scale)


def test_ring_buffer_matches_full_cache_within_window():
    """With seq shorter than the window, ring-buffer decode == full-cache."""
    cfg = get_config("smollm_360m").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, W = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0,
                                cfg.vocab_size)
    # full cache
    s_full, _, _ = model.prefill(cfg, params, {"tokens": tokens}, 32)
    # ring cache of size W (pad prefill cache into a ring: use decode only)
    s_ring, _, _ = model.prefill(cfg, params, {"tokens": tokens}, W)
    tok = jnp.zeros((B,), jnp.int32)
    for i in range(4):
        pos = jnp.asarray(8 + i, jnp.int32)
        lf, _, s_full = model.decode_step(cfg, params, tok, s_full, pos)
        lr, _, s_ring = model.decode_step(cfg, params, tok, s_ring, pos,
                                          window=W)
        np.testing.assert_allclose(np.asarray(lf, np.float32),
                                   np.asarray(lr, np.float32),
                                   rtol=2e-3, atol=2e-3, err_msg=f"step {i}")
        tok = jnp.argmax(lf[:, :cfg.vocab_size], -1).astype(jnp.int32)
