"""End-to-end behaviour tests for the ORCA system (paper-level claims on a
small synthetic corpus) + driver smoke tests."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.pipeline import evaluate_probe, run_orca
from repro.core.probe import ProbeConfig
from repro.trajectories import corpus_splits, ood_benchmark

# the deprecated shims (ServingEngine.serve / run_orca) are exercised here
# ON PURPOSE as equality baselines — silence their DeprecationWarning
pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="module")
def orca_run():
    train, cal, test = corpus_splits(240, 90, 90, d_phi=96, seed=1)
    out = run_orca(train, cal, test, mode="supervised",
                   pc=ProbeConfig(d_phi=96), deltas=(0.1, 0.2), epochs=25,
                   seed=1)
    return train, cal, test, out


def test_risk_control_holds(orca_run):
    """LTT guarantee: test error <= delta (+ finite-sample slack) whenever a
    threshold was selected."""
    *_, out = orca_run
    for method in ("ttt", "static"):
        for r in out[method].results:
            if np.isfinite(r.lam):
                assert r.error <= r.delta + 0.08, (method, r.delta, r.error)


def test_ttt_beats_static_in_distribution(orca_run):
    *_, out = orca_run
    t = out["ttt"].at(0.1)
    s = out["static"].at(0.1)
    assert t.savings >= s.savings - 0.02, (t.savings, s.savings)


def test_ttt_ood_gap(orca_run):
    """Zero-shot OOD: TTT savings should exceed static by a clear margin
    (paper's Table 3 headline)."""
    train, cal, test, out = orca_run
    probe, static = out["_probe"], out["_static"]
    ood = ood_benchmark("math500", 90, d_phi=96)
    e_t = evaluate_probe(probe.scores(cal), cal, probe.scores(ood), ood,
                         "supervised", (0.1,)).results[0]
    e_s = evaluate_probe(static.scores(cal.phis, cal.mask), cal,
                         static.scores(ood.phis, ood.mask), ood,
                         "supervised", (0.1,)).results[0]
    assert e_t.savings > e_s.savings, (e_t.savings, e_s.savings)


def test_consistent_mode_is_label_free_and_works(orca_run):
    train, cal, test, _ = orca_run
    out = run_orca(train, cal, test, mode="consistent",
                   pc=ProbeConfig(d_phi=96), deltas=(0.1,), epochs=25,
                   include_static=False, seed=1)
    r = out["ttt"].results[0]
    assert r.error <= 0.1 + 0.08
    assert r.savings >= 0.0


def test_train_driver_cli(tmp_path):
    """The training driver runs end-to-end (reduced config, 25 steps) and
    reduces the loss (exit code 0 asserts this)."""
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "smollm-360m",
         "--reduced", "--steps", "25", "--batch", "4", "--seq", "64",
         "--lr", "1e-3", "--ckpt-dir", str(tmp_path / "ck"),
         "--log-every", "10"],
        capture_output=True, text=True, timeout=900, env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert (tmp_path / "ck").exists()


def test_dryrun_cli_skip_path():
    """The dry-run CLI handles the documented skip without device setup."""
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "whisper-tiny",
         "--shape", "long_500k"],
        capture_output=True, text=True, timeout=600, env=env)
    assert proc.returncode == 0, proc.stderr
    assert '"skip"' in proc.stdout
