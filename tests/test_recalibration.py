"""Online recalibration under drift (beyond-paper extension)."""
import numpy as np

from repro.core.pipeline import make_labels, train_ttt_probe
from repro.core.probe import ProbeConfig
from repro.core.recalibration import OnlineRecalibrator, RecalibratorConfig
from repro.trajectories import corpus_splits, ood_benchmark


def _stream(rec, probe, ts, lab):
    """Feed problems one by one; return (errors, savings) realized online."""
    scores = probe.scores(ts)
    errs, savs = [], []
    for i in range(len(ts)):
        T = ts.lengths[i]
        s = scores[i, :T]
        l = lab[i, :T]
        stop = rec.decide(s)
        errs.append(1.0 if (stop < T and l[min(stop, T - 1)] < 0.5) else 0.0)
        savs.append(1.0 - min(stop + 1, T) / T)
        rec.observe(s, l)
    return np.asarray(errs), np.asarray(savs)


def test_recalibrator_converges_and_controls_risk():
    train, cal, _ = corpus_splits(240, 200, 10, d_phi=96, seed=3)
    probe = train_ttt_probe(train, "supervised", ProbeConfig(d_phi=96),
                            epochs=20, seed=3)
    lab = make_labels(cal, "supervised")
    rec = OnlineRecalibrator(RecalibratorConfig(delta=0.15, window=150,
                                                every=20, min_window=40))
    errs, savs = _stream(rec, probe, cal, lab)
    # after warmup the recalibrator should certify a threshold and save
    assert np.isfinite(rec.lam)
    tail_err = errs[60:].mean()
    assert tail_err <= 0.15 + 0.1
    assert savs[60:].mean() > 0.0


def test_recalibrator_adapts_to_shift():
    """A distribution shift mid-stream: risk stays controlled because the
    window re-certifies lambda on post-shift evidence."""
    train, cal, _ = corpus_splits(240, 120, 10, d_phi=96, seed=4)
    probe = train_ttt_probe(train, "supervised", ProbeConfig(d_phi=96),
                            epochs=20, seed=4)
    ood = ood_benchmark("gpqa", 150, d_phi=96)  # static-hostile shift
    lab_a = make_labels(cal, "supervised")
    lab_b = make_labels(ood, "supervised")
    rec = OnlineRecalibrator(RecalibratorConfig(delta=0.15, window=100,
                                                every=20, min_window=40))
    _stream(rec, probe, cal, lab_a)
    errs_b, savs_b = _stream(rec, probe, ood, lab_b)
    tail = errs_b[60:]
    assert tail.mean() <= 0.15 + 0.12, tail.mean()
    # safety fallback never triggers a crash; history shows recalibrations
    assert len(rec.history) >= 3
