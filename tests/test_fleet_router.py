"""Multi-host fleet serving: FleetRouter stop-decision byte-identity
across host counts (policy x packing x paged), prefix-affine placement,
gang atomicity across hosts, pressure-balanced placement, the ServeConfig
consolidation (validation, from_args, deprecation shims) and the
hypothesis sweep over page ownership."""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api as orca
from repro.configs import get_config
from repro.core.probe import ProbeConfig, init_outer
from repro.models import build
from repro.serving import (FleetRouter, RoundRobinPlacement, ServeConfig,
                           make_placement, make_request, replay_model,
                           replay_params, replay_requests, serve_replay)

from tests._hypothesis_stub import given, settings, st

N_TRAJ, T_STEPS, D_PHI = 10, 20, 6


@pytest.fixture(scope="module")
def replay_bank():
    rs = np.random.RandomState(7)
    drift = np.linspace(0, 1.2, T_STEPS)[None, :, None]
    bank = (rs.randn(N_TRAJ, T_STEPS, D_PHI) * 0.3
            + drift * rs.rand(N_TRAJ, 1, D_PHI)).astype(np.float32)
    theta = {"W0": (rs.randn(D_PHI) * 0.4).astype(np.float32),
             "b0": np.float32(-0.2)}
    return bank, theta


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("smollm_360m").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _probe(mcfg, bias):
    pc = ProbeConfig(d_phi=mcfg.d_model, smooth_window=2)
    theta = init_outer(pc, jax.random.PRNGKey(1))
    theta["b0"] = jnp.asarray(float(bias))
    return pc, theta


def _stops(requests):
    return [(r.stop_step, r.state.name, tuple(r.tokens)) for r in requests]


# ---------------------------------------------------------------------------
# the fleet invariant: stops byte-identical to single-host serving

@pytest.mark.parametrize("policy,pack,paged,chunk", [
    ("fifo", False, False, None),
    ("fifo", True, True, 2),
    ("priority", True, False, 2),
    ("priority", False, True, None),
    ("edf", True, True, 2),
    ("ttft", False, True, 2),
])
def test_stops_byte_identical_across_host_counts(replay_bank, policy,
                                                 pack, paged, chunk):
    """Per-request stop decisions (and every decoded token) are
    byte-identical for 1-host vs 2-host vs 4-host fleets under every
    policy x packing x paged combination — each host runs the unchanged
    single-host scheduler, so placement cannot change a stop."""
    bank, theta = replay_bank
    kw = dict(lam=0.62, burn_in=3, n_slots=3, policy=policy,
              pack_chunks=pack, paged=paged, block_size=4,
              chunk_tokens=chunk)
    prios = [i % 2 for i in range(N_TRAJ)]
    base, _, _ = serve_replay(bank, theta, n_hosts=1, priorities=prios,
                              **kw)
    for n_hosts in (2, 4):
        got, fm, _ = serve_replay(bank, theta, n_hosts=n_hosts,
                                  priorities=prios, parallel_hosts=False,
                                  **kw)
        assert _stops(got) == _stops(base), \
            f"stops diverged at {n_hosts} hosts"
        assert fm.n_hosts == n_hosts
        assert {r.host for r in got} <= set(range(n_hosts))


def test_parallel_stepping_matches_serial(replay_bank):
    """Concurrent host stepping (the thread pool) changes wall time only:
    stops and tokens match the serial fleet byte for byte."""
    bank, theta = replay_bank
    kw = dict(lam=0.62, burn_in=3, n_slots=3, paged=True, block_size=4)
    a, _, _ = serve_replay(bank, theta, n_hosts=2, parallel_hosts=False,
                           **kw)
    b, _, _ = serve_replay(bank, theta, n_hosts=2, parallel_hosts=True,
                           **kw)
    assert _stops(a) == _stops(b)


# ---------------------------------------------------------------------------
# placement

def test_prefix_affinity_routes_to_donor_host(small_model):
    """Same-prompt traffic lands on the host already holding the donor
    pages: every follower's prefill collapses to a page-table copy
    (prefill_skips) on ONE host instead of cold prefills spread across
    the fleet — and stops stay byte-identical under the locality-blind
    round-robin placement."""
    model, params = small_model
    pc, theta = _probe(model.cfg, 3.0)
    cfg = ServeConfig(tokens_per_step=2, max_new_tokens=12, lam=0.6,
                      burn_in=1, n_slots=4, paged=True, block_size=4)
    prompt = jax.random.randint(jax.random.PRNGKey(3), (8,), 0,
                                model.cfg.vocab_size)

    def run(placement):
        router = FleetRouter(model, params, pc, theta, cfg, n_hosts=2,
                             placement=placement, parallel_hosts=False)
        done, fm = router.run([make_request(np.asarray(prompt))
                               for _ in range(4)])
        return done, fm, router

    done, fm, router = run("pressure")
    assert fm.prefill_skips == 3          # one cold prefill, three skips
    assert fm.routed_affine == 3
    assert len({r.host for r in done}) == 1   # all on the donor host
    rr_done, rr_fm, _ = run(RoundRobinPlacement())
    # round-robin is affinity-blind; at most coincidental donor landings
    assert rr_fm.routed_affine < fm.routed_affine
    assert len({r.host for r in rr_done}) == 2   # spread: 2 hosts
    assert rr_fm.prefill_skips == 2       # one cold prefill PER host
    assert _stops(rr_done) == _stops(done)


def test_gang_never_split_across_hosts(replay_bank):
    """A self-consistency gang places as one unit: every sample of a
    group lands on the same host, and a gang larger than any host's slot
    count raises the fleet-flavored error instead of half-placing."""
    bank, theta = replay_bank
    reqs = replay_requests([T_STEPS] * 8)
    for i, r in enumerate(reqs):
        r.group_id, r.sample_idx = i // 4, i % 4
    pc = ProbeConfig(d_phi=D_PHI, smooth_window=4)
    cfg = ServeConfig(tokens_per_step=1, max_new_tokens=T_STEPS, lam=0.62,
                      burn_in=3, n_slots=4, paged=True, block_size=4)
    router = FleetRouter(replay_model(bank), replay_params(bank), pc,
                         theta, cfg, n_hosts=2, parallel_hosts=False)
    done, _ = router.run(reqs)
    for gid in (0, 1):
        hosts = {r.host for r in done if r.group_id == gid}
        assert len(hosts) == 1, f"group {gid} split across hosts {hosts}"

    big = replay_requests([T_STEPS] * 5)
    for i, r in enumerate(big):
        r.group_id, r.sample_idx = 0, i
    router = FleetRouter(replay_model(bank), replay_params(bank), pc,
                         theta, cfg, n_hosts=2, parallel_hosts=False)
    with pytest.raises(ValueError, match="never split across hosts"):
        router.submit(big)


def test_pressure_balanced_placement_under_burst(replay_bank):
    """A skewed burst (every request submitted at once) spreads across
    the fleet: the pressure placement balances outstanding samples, so
    neither host serves the whole burst."""
    bank, theta = replay_bank
    done, fm, router = serve_replay(
        bank, theta, n_hosts=2, parallel_hosts=False, lam=0.62,
        burn_in=3, n_slots=3)
    counts = [sum(1 for r in done if r.host == h) for h in (0, 1)]
    assert sorted(counts) == [5, 5], counts
    assert fm.n_hosts == 2


def test_pressure_snapshot_fields(replay_bank):
    """``OrcaScheduler.pressure()`` exports the gossip snapshot at any
    session point, and the router's ``pressures()`` mirrors its hosts."""
    bank, theta = replay_bank
    pc = ProbeConfig(d_phi=D_PHI, smooth_window=4)
    cfg = ServeConfig(tokens_per_step=1, max_new_tokens=T_STEPS,
                      lam=0.62, burn_in=3, n_slots=3, paged=True,
                      block_size=4)
    router = FleetRouter(replay_model(bank), replay_params(bank), pc,
                         theta, cfg, n_hosts=2, parallel_hosts=False)
    for p in router.pressures():          # before any submit
        assert p.free_slots == p.n_slots == 3
        assert p.outstanding == 0
    router.submit(replay_requests([T_STEPS] * 8))
    router.step()
    ps = router.pressures()
    assert [p.host for p in ps] == [0, 1]
    assert sum(p.n_running + p.n_prefilling for p in ps) > 0
    assert all(p.pool_blocks > 0 for p in ps)
    while router.step():
        pass
    done, _ = router.drain()
    assert all(r.done for r in done)


# ---------------------------------------------------------------------------
# ServeConfig: the consolidated API

def test_serveconfig_validation_names_the_fix():
    """Every invalid configuration fails at construction with an error
    naming the fix, no matter which entry point would have built it."""
    for kwargs, match in [
        (dict(tokens_per_step=0), "tokens_per_step"),
        (dict(max_new_tokens=0), "max_new_tokens"),
        (dict(block_size=0), "block_size"),
        (dict(pack_max=0), "pack_max"),
        (dict(probe_impl="magic"), "probe_impl"),
        (dict(n_hosts=0), "n_hosts"),
        (dict(group_size=0), "group_size"),
        (dict(group_size=8, n_slots=4), "gang admission"),
        (dict(consensus=0.9), "group_size=1"),
        (dict(consensus=True, group_size=2), "not a threshold"),
        (dict(consensus=1.5, group_size=2), "outside"),
        (dict(consensus_delta=0.1), "without consensus"),
    ]:
        with pytest.raises(ValueError, match=match):
            ServeConfig(**kwargs)


def test_serveconfig_is_frozen_and_normalizes():
    cfg = ServeConfig(num_blocks=0, chunk_tokens=0, cache_len=0,
                      token_budget=0)
    assert cfg.num_blocks is None and cfg.chunk_tokens is None
    assert cfg.cache_len is None and cfg.token_budget is None
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.n_slots = 8
    # replace() re-validates
    with pytest.raises(ValueError, match="gang admission"):
        dataclasses.replace(cfg, group_size=99)


def test_serveconfig_from_args_maps_cli_flags():
    """from_args reads the launch/serve.py flag names (slots -> n_slots,
    no_pack/no_preempt invert, 0 -> None), partial namespaces work and
    overrides win."""
    ns = argparse.Namespace(slots=6, paged=True, block_size=8,
                            num_blocks=0, chunk_tokens=4, token_budget=0,
                            policy="priority", no_pack=True, pack_max=2,
                            group_size=2, no_preempt=True, hosts=3,
                            tokens_per_step=2, max_new_tokens=32,
                            burn_in=1)
    cfg = ServeConfig.from_args(ns, lam=0.7)
    assert cfg.n_slots == 6 and cfg.paged and cfg.block_size == 8
    assert cfg.num_blocks is None and cfg.chunk_tokens == 4
    assert cfg.token_budget is None and cfg.policy == "priority"
    assert cfg.pack_chunks is False and cfg.pack_max == 2
    assert cfg.preemption is False and cfg.n_hosts == 3
    assert cfg.lam == 0.7 and cfg.tokens_per_step == 2
    partial = ServeConfig.from_args(argparse.Namespace(slots=2))
    assert partial.n_slots == 2 and partial.n_hosts == 1
    override = ServeConfig.from_args(ns, n_slots=9, lam=0.5)
    assert override.n_slots == 9


# ---------------------------------------------------------------------------
# api facade: config path, legacy shims, duck-typed serve_requests

class _StubCalibrator:
    """Minimal Calibrator surface engine()/fleet() consume."""

    def __init__(self, pc, theta, lam=0.62):
        self._pc, self._theta, self._lam = pc, theta, lam

    def serving_params(self):
        return self._pc, self._theta

    def threshold(self):
        return self._lam


@pytest.fixture(scope="module")
def replay_calibrator(replay_bank):
    bank, theta = replay_bank
    pc = ProbeConfig(d_phi=D_PHI, smooth_window=4)
    return (replay_model(bank), replay_params(bank),
            _StubCalibrator(pc, theta))


def test_engine_legacy_kwargs_shim_matches_config(replay_calibrator):
    """The pre-ServeConfig kwargs sprawl still works — as a
    DeprecationWarning-emitting shim producing byte-identical serving."""
    model, params, cal = replay_calibrator
    kw = dict(tokens_per_step=1, max_new_tokens=T_STEPS, burn_in=3,
              n_slots=3, paged=True, block_size=4)
    with pytest.warns(DeprecationWarning, match="ServeConfig"):
        legacy = orca.engine(model, params, cal, **kw)
    blessed = orca.engine(model, params, cal,
                          config=ServeConfig(lam=0.62, **kw))
    l_done, _ = legacy.run(replay_requests([T_STEPS] * N_TRAJ))
    b_done, _ = blessed.run(replay_requests([T_STEPS] * N_TRAJ))
    assert _stops(l_done) == _stops(b_done)


def test_engine_config_rejects_kwarg_mix(replay_calibrator):
    model, params, cal = replay_calibrator
    cfg = ServeConfig(lam=0.62, tokens_per_step=1)
    with pytest.raises(ValueError, match="ambiguous"):
        orca.engine(model, params, cal, config=cfg, n_slots=3)
    with pytest.warns(DeprecationWarning, match="serve="):
        orca.engine(model, params, cal, serve=cfg)
    with pytest.raises(ValueError, match="not both"):
        orca.engine(model, params, cal, serve=cfg, lam=0.5)


def test_serve_requests_duck_typed_over_scheduler_and_router(
        replay_calibrator):
    """One entry point drives both servers: serve_requests accepts an
    OrcaScheduler or a FleetRouter (same submit/step/drain protocol) and
    the stops match byte for byte."""
    model, params, cal = replay_calibrator
    cfg = ServeConfig(lam=0.62, tokens_per_step=1,
                      max_new_tokens=T_STEPS, burn_in=3, n_slots=3)
    prompts = np.arange(N_TRAJ, dtype=np.int64)[:, None]
    sched = orca.engine(model, params, cal, config=cfg)
    router = orca.fleet(model, params, cal, config=cfg, n_hosts=2,
                        parallel_hosts=False)
    s_done, s_fm = orca.serve_requests(sched, prompts)
    r_done, r_fm = orca.serve_requests(router, prompts)
    assert _stops(s_done) == _stops(r_done)
    assert s_fm.n_hosts == 1 and r_fm.n_hosts == 2


def test_deprecated_serving_engine_serve_warns(small_model):
    from repro.serving import ServingEngine
    model, params = small_model
    pc, theta = _probe(model.cfg, 3.0)
    cfg = ServeConfig(tokens_per_step=2, max_new_tokens=8, lam=0.6,
                      burn_in=1)
    eng = ServingEngine(model, params, pc, theta, cfg)
    batch = {"tokens": jnp.zeros((1, 4), jnp.int32)}
    with pytest.warns(DeprecationWarning, match="static-batch baseline"):
        eng.serve(batch, prompt_len=4, cache_len=16)


# ---------------------------------------------------------------------------
# property sweep: ownership + refcounts under random fleets

@given(seed=st.integers(min_value=0, max_value=10_000),
       n_hosts=st.integers(min_value=1, max_value=3),
       policy=st.sampled_from(["fifo", "priority"]),
       paged=st.booleans())
@settings(max_examples=8, deadline=None)
def test_fuzz_no_cross_host_ownership(seed, n_hosts, policy, paged):
    """Random fleets: every request terminates on exactly one host, no
    host's pool ever references another host's pages (pools are disjoint
    objects — cross-host ownership would surface as refcount leaks), and
    every refcount drains to zero after the session."""
    rs = np.random.RandomState(seed)
    bank = (rs.randn(6, 12, 4) * 0.4
            + np.linspace(0, 1, 12)[None, :, None]).astype(np.float32)
    theta = {"W0": (rs.randn(4) * 0.4).astype(np.float32),
             "b0": np.float32(-0.1)}
    prios = rs.randint(0, 3, size=6).tolist()
    done, fm, server = serve_replay(
        bank, theta, n_hosts=n_hosts, parallel_hosts=False,
        priorities=prios, lam=0.6, burn_in=2, n_slots=2, paged=paged,
        block_size=4, policy=policy)
    assert all(r.done for r in done)
    hosts = [server] if n_hosts == 1 else server.hosts
    for h in hosts:
        if paged:
            h.pool.check()
            assert h.pool.blocks_in_use == 0
            assert h.pool.num_free == h.pool.num_usable
    if n_hosts > 1:
        assert {r.host for r in done} <= set(range(n_hosts))
        placement = make_placement(None)
        assert placement.select_host(
            [done[0]], server.pressures(), need_slots=1,
            need_pages=0) in range(n_hosts)
