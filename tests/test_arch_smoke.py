"""Per-architecture smoke tests on REDUCED variants (CPU).

Every assigned architecture must (a) instantiate a reduced config of the same
family (2 layers, d_model<=512, <=4 experts), (b) run one forward/train step,
(c) run prefill + a few decode steps, asserting output shapes and no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, InputShape, get_config
from repro.models import build

SMOKE_TRAIN = InputShape("smoke_train", 64, 2, "train")


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch(request):
    cfg = get_config(request.param).reduced()
    return build(cfg)


def test_full_config_matches_assignment(arch):
    full = get_config(arch.cfg.name)
    assert full.arch_type in ("dense", "moe", "ssm", "hybrid", "vlm", "audio")
    # reduced invariants from the assignment
    assert arch.cfg.n_layers == 2
    assert arch.cfg.d_model <= 512
    if arch.cfg.moe is not None:
        assert arch.cfg.moe.n_experts <= 4


def test_forward_and_loss(arch):
    rng = jax.random.PRNGKey(0)
    params = arch.init(rng)
    batch = arch.make_batch(jax.random.PRNGKey(1), SMOKE_TRAIN)
    loss, metrics = arch.loss(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch.cfg.name}: loss={loss}"
    logits, hidden, aux = arch.forward(arch.cfg, params, batch)
    assert logits.shape[-1] == arch.cfg.padded_vocab()
    assert hidden.shape[-1] == arch.cfg.d_model
    assert np.isfinite(np.asarray(hidden, np.float32)).all()


def test_train_step_improves(arch):
    """One SGD step on the smoke batch must reduce the loss (gradients flow)."""
    rng = jax.random.PRNGKey(0)
    params = arch.init(rng)
    batch = arch.make_batch(jax.random.PRNGKey(1), SMOKE_TRAIN)

    def lf(p):
        return arch.loss(p, batch)[0]

    l0, grads = jax.value_and_grad(lf)(params)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0
    params2 = jax.tree.map(lambda p, g: p - 2e-2 * g.astype(p.dtype) /
                           (gnorm.astype(p.dtype) + 1e-6), params, grads)
    l1 = lf(params2)
    assert float(l1) < float(l0), f"{arch.cfg.name}: {l0} -> {l1}"


def test_prefill_decode(arch):
    rng = jax.random.PRNGKey(0)
    params = arch.init(rng)
    cfg = arch.cfg
    B, S_prompt, cache_len = 2, 16, 32
    shape = InputShape("smoke_prefill", S_prompt + (cfg.frontend.n_tokens if
                       cfg.arch_type in ("vlm",) else 0) + cfg.n_meta_tokens,
                       B, "prefill")
    batch = arch.make_batch(jax.random.PRNGKey(1), shape)
    state, last_h, h_all = arch.prefill(cfg, params, batch, cache_len)
    if last_h is not None:
        assert last_h.shape == (B, cfg.d_model)
        assert np.isfinite(np.asarray(last_h, np.float32)).all()
    # a few decode steps
    tok = jnp.zeros((B,), jnp.int32)
    prompt_len = shape.seq_len if cfg.arch_type != "audio" else 0
    cache_len_g, window = arch.decode_geometry(
        InputShape("d", cache_len, B, "decode"))
    for i in range(3):
        pos = jnp.asarray(prompt_len + i, jnp.int32)
        logits, hidden, state = arch.decode_step(cfg, params, tok, state, pos,
                                                 window=window)
        assert logits.shape == (B, cfg.padded_vocab())
        assert hidden.shape == (B, cfg.d_model)
        assert np.isfinite(np.asarray(hidden, np.float32)).all(), cfg.name
        tok = jnp.argmax(logits[:, :cfg.vocab_size], -1).astype(jnp.int32)


def test_divisibility_for_model_axis(arch):
    """Full-scale sharding invariants: TP dims divisible by the 16-way model
    axis, experts divisible too (checked on the FULL config)."""
    cfg = get_config(arch.cfg.name)
    assert cfg.d_ff % 16 == 0
    assert (cfg.n_heads * cfg.d_head) % 16 == 0
    assert (cfg.n_kv_heads * cfg.d_head) % 16 == 0
    assert cfg.d_model % 16 == 0
    assert cfg.padded_vocab() % 256 == 0
    if cfg.moe is not None:
        assert cfg.moe.n_experts % 16 == 0
