"""Group serving: gang admission, conformal consensus stop, mid-flight
sibling cancellation — and the schedule-invariance contract (the group
layer is INERT for ungrouped or consensus-off fleets: stop decisions are
byte-identical to the classic engine under every policy/packing/paging
configuration)."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api as orca
from repro.configs import get_config
from repro.core import stopping as S
from repro.core.calibrator import GroupCalibrator, GroupTrace
from repro.core.probe import ProbeConfig, init_outer
from repro.models import build
from repro.serving import (OrcaScheduler, RequestState, ServeConfig,
                           group_requests, make_group, make_group_fleet,
                           make_request, replay_model, replay_params)
from repro.trajectories.synthetic import TrajectoryDistribution, generate
from tests._hypothesis_stub import given, settings, st

D = 24


def _bank(n, t, seed=0, scale=0.6):
    rs = np.random.RandomState(seed)
    return (rs.randn(n, t, D) * scale).astype(np.float32)


def _probe(bias, smooth_window=1, d=D):
    pc = ProbeConfig(d_phi=d, smooth_window=smooth_window)
    theta = init_outer(pc, jax.random.PRNGKey(1))
    theta["b0"] = jnp.asarray(float(bias))
    return pc, theta


def _replay_reqs(n, lengths, *, group_size=None, prompt_len=1):
    """Replay requests; ``group_size`` assigns consecutive group ids."""
    reqs = []
    for i in range(n):
        gid = (i // group_size) if group_size else None
        sj = (i % group_size) if group_size else 0
        reqs.append(make_request(np.full((prompt_len,), i, np.int64),
                                 max_new_tokens=int(lengths[i]),
                                 group_id=gid, sample_idx=sj))
    return reqs


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("smollm_360m").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


# ---------------------------------------------------------------------------
# consensus math (core.stopping)

def test_weighted_vote_tie_breaks_toward_smaller_hash():
    ans, agr = S.weighted_vote([1.0, 1.0], [5, 3], [True, True])
    assert (ans, agr) == (3, 0.5)


def test_weighted_vote_inactive_and_nonpositive():
    assert S.weighted_vote([0.9, 0.9], [1, 2], [False, False]) == (-1, 0.0)
    # negative confidences are clipped, not sign-flipped into votes
    ans, agr = S.weighted_vote([-1.0, 0.5], [7, 2], [True, True])
    assert (ans, agr) == (2, 1.0)


def test_consensus_trace_freezes_votes_at_stop_and_length():
    # sample 0 stops at tau=1 (keeps voting answer 8 with score 0.9);
    # sample 1 runs to its length-2 trajectory end then freezes
    scores = np.array([[0.2, 0.9, 0.1, 0.1],
                       [0.3, 0.3, 0.0, 0.0]])
    answers = np.array([[7, 8, 9, 9],
                        [8, 8, 0, 0]])
    lengths = np.array([4, 2])
    ans, agr = S.consensus_trace(scores, answers, lengths,
                                 per_sample_tau=np.array([1, 10]))
    # t=0: votes (7@.2, 8@.3) -> 8; t>=1: both frozen on 8
    assert ans.tolist() == [8, 8, 8, 8]
    np.testing.assert_allclose(agr[1:], 1.0)


def test_consensus_stop_times_burn_in_and_never():
    agr = np.array([1.0, 1.0, 0.0, 0.95])
    taus = S.consensus_stop_times(agr, [0.9, 2.0], burn_in=2)
    assert taus.tolist() == [3, 4]      # first crossing >= burn-in; never=Tg


def test_consensus_risk_charges_only_wrong_fires():
    tau_g = np.array([2, 4, 3])          # Tg=4: group 1 never fired
    ans = np.array([[5, 5, 5, 5], [1, 1, 1, 1], [9, 9, 9, 9]])
    risk = [float(S.consensus_risk(np.array([t]), a, truth=5)[0])
            for t, a in zip(tau_g, ans)]
    assert risk == [0.0, 0.0, 1.0]


# ---------------------------------------------------------------------------
# GroupCalibrator

def test_group_calibrator_threshold_requires_calibrate():
    with pytest.raises(RuntimeError, match="calibrate"):
        GroupCalibrator().threshold()


def test_group_calibrator_decide_gates():
    gc = GroupCalibrator(min_votes=2, burn_in=2, lam=0.6)
    # a lone voter never fires, however confident
    fire, _, _ = gc.decide([[0.9, 0.9, 0.9]], [[4, 4, 4]])
    assert not fire
    # two agreeing voters before burn-in: gated
    fire, _, _ = gc.decide([[0.9], [0.9]], [[4], [4]])
    assert not fire
    # past burn-in with full agreement: fires with the right answer
    fire, ans, agr = gc.decide([[0.9, 0.9, 0.9], [0.8, 0.8, 0.8]],
                               [[4, 4, 4], [4, 4, 4]])
    assert fire and ans == 4 and agr == pytest.approx(1.0)
    # split vote below threshold: no fire
    fire, _, agr = gc.decide([[0.5, 0.5, 0.5], [0.5, 0.5, 0.5]],
                             [[4, 4, 4], [9, 9, 9]])
    assert not fire and agr == pytest.approx(0.5)


def test_group_calibrator_calibrate_controls_group_risk():
    rs = np.random.RandomState(3)
    t, n, delta = 20, 3, 0.5
    traces = []
    for g in range(20):
        scores = rs.rand(n, t) * 0.5 + 0.4
        # one adversarial group votes a wrong answer unanimously; the rest
        # vote their truth from the start
        truth, vote = (g, 99) if g == 0 else (g, g)
        answers = np.full((n, t), vote)
        traces.append(GroupTrace(scores=scores, answers=answers,
                                 lengths=np.full(n, t), truth=truth))
    gc = GroupCalibrator(min_votes=2, burn_in=2)
    lam = gc.calibrate(traces, delta, eps=0.2)
    assert np.isfinite(lam) and gc.delta == delta
    fired_wrong = 0
    for tr in traces:
        a, g = S.consensus_trace(tr.scores, tr.answers, tr.lengths)
        tau = S.consensus_stop_times(g, [lam], burn_in=2)[0]
        fired_wrong += int(tau < t and a[tau] != tr.truth)
    assert fired_wrong / len(traces) <= delta


# ---------------------------------------------------------------------------
# group_requests partitioning

def test_group_requests_units_keep_arrival_order():
    g0 = make_group(np.zeros(4, np.int64), 2, group_id=0)
    solo = make_request(np.ones(4, np.int64))
    g1 = make_group(np.zeros(4, np.int64), 2, group_id=1)
    units, groups = group_requests([g0[0], solo, g0[1], g1[0], g1[1]])
    assert [len(u) for u in units] == [2, 1, 2]
    assert units[0] == g0 and units[1] == [solo] and units[2] == g1
    assert {g.group_id for g in groups} == {0, 1}


def test_group_requests_renumbers_duplicate_sample_idx():
    reqs = [make_request(np.zeros(2, np.int64), group_id=5)
            for _ in range(3)]                     # all sample_idx=0
    units, groups = group_requests(reqs)
    assert len(units) == 1 and groups[0].size == 3
    assert sorted(r.sample_idx for r in reqs) == [0, 1, 2]


# ---------------------------------------------------------------------------
# validation errors (scheduler + api facade) name the fix

def test_scheduler_rejects_bad_consensus_values():
    args = (None, None, ProbeConfig(d_phi=4), None, ServeConfig(lam=0.5))
    with pytest.raises(ValueError, match="not a threshold"):
        OrcaScheduler(*args, consensus=True)
    with pytest.raises(ValueError, match=r"outside \(0, 1\]"):
        OrcaScheduler(*args, consensus=1.5)
    with pytest.raises(ValueError, match="no threshold"):
        OrcaScheduler(*args, consensus=GroupCalibrator())
    with pytest.raises(ValueError, match="must be a GroupCalibrator"):
        OrcaScheduler(*args, consensus="0.9")


def test_scheduler_rejects_group_larger_than_fleet():
    bank = _bank(3, 4)
    pc, theta = _probe(0.0)
    sched = OrcaScheduler(replay_model(bank), replay_params(bank), pc, theta,
                          ServeConfig(tokens_per_step=1, max_new_tokens=4,
                                      lam=2.0),
                          n_slots=2)
    with pytest.raises(ValueError, match="gang admission"):
        sched.run(_replay_reqs(3, [4, 4, 4], group_size=3))


def test_api_engine_validates_group_knobs():
    dummy = object()                  # errors fire before serving_params()
    with pytest.raises(ValueError, match="group_size"):
        orca.engine(None, None, dummy, group_size=0)
    with pytest.raises(ValueError, match="raising n_slots"):
        orca.engine(None, None, dummy, n_slots=2, group_size=3)
    with pytest.raises(ValueError, match="group_size >= 2"):
        orca.engine(None, None, dummy, group_size=1, consensus=0.9)
    with pytest.raises(ValueError, match="consensus_delta"):
        orca.engine(None, None, dummy, group_size=2,
                    consensus_delta=0.1)
    stale = GroupCalibrator(lam=0.7)
    stale.delta = 0.2
    with pytest.raises(ValueError, match="does not match"):
        orca.engine(None, None, dummy, group_size=2, consensus=stale,
                    consensus_delta=0.3)


# ---------------------------------------------------------------------------
# schedule invariance: gang scheduling w/o consensus is byte-inert

@pytest.mark.parametrize("policy", ["fifo", "priority", "ttft"])
@pytest.mark.parametrize("pack", [False, True])
@pytest.mark.parametrize("paged", [False, True])
def test_grouping_without_consensus_is_byte_inert(policy, pack, paged):
    """{fifo,priority,ttft} x {packed,unpacked} x {paged,dense}: the same
    fleet served ungrouped and as gang-scheduled (consensus-off) groups
    produces identical stops, scores and tokens, request for request."""
    n, t = 9, 12
    bank = _bank(n, t, seed=4)
    lengths = [12, 8, 10, 12, 6, 12, 9, 12, 7]
    pc, theta = _probe(1.0, smooth_window=2)
    cfg = ServeConfig(tokens_per_step=1, max_new_tokens=t, lam=0.62,
                      burn_in=2)

    def run(group_size):
        sched = OrcaScheduler(replay_model(bank), replay_params(bank),
                              pc, theta, cfg, n_slots=4, paged=paged,
                              block_size=4, chunk_tokens=3,
                              pack_chunks=pack, policy=policy)
        reqs = _replay_reqs(n, lengths, group_size=group_size)
        for i, r in enumerate(reqs):
            r.priority = i % 2
        done, fleet = sched.run(reqs)
        return done, fleet

    base, fleet_b = run(None)
    grouped, fleet_g = run(3)
    for rb, rg in zip(base, grouped):
        assert rb.stop_step == rg.stop_step
        assert rb.tokens == rg.tokens
        np.testing.assert_allclose(np.array(rb.scores),
                                   np.array(rg.scores), atol=1e-6)
        assert rg.state in (RequestState.STOPPED, RequestState.FINISHED)
    assert fleet_g.samples_cancelled == 0 and fleet_g.consensus_groups == 0


def test_singleton_groups_match_ungrouped_oracle():
    """group_size=1 (every request its own group) is the classic engine."""
    n, t = 6, 10
    bank = _bank(n, t, seed=9)
    pc, theta = _probe(1.2, smooth_window=2)
    cfg = ServeConfig(tokens_per_step=1, max_new_tokens=t, lam=0.6,
                      burn_in=1)

    def run(group_size):
        sched = OrcaScheduler(replay_model(bank), replay_params(bank),
                              pc, theta, cfg, n_slots=3, paged=True,
                              block_size=4)
        done, _ = sched.run(_replay_reqs(n, [t] * n,
                                         group_size=group_size))
        return done

    for rb, rg in zip(run(None), run(1)):
        assert rb.stop_step == rg.stop_step and rb.tokens == rg.tokens


# ---------------------------------------------------------------------------
# gang admission

def test_gang_admission_is_atomic():
    """All samples of a group land on the SAME engine step — a group is
    never half-resident, even when slots free up one at a time."""
    n, t = 9, 8
    bank = _bank(n, t, seed=5)
    pc, theta = _probe(0.0)                       # no stops: budget path
    cfg = ServeConfig(tokens_per_step=1, max_new_tokens=t, lam=2.0)
    sched = OrcaScheduler(replay_model(bank), replay_params(bank), pc, theta,
                          cfg, n_slots=4, paged=True, block_size=4)
    # skewed budgets: slots return one by one, the next gang must wait for 3
    lengths = [8, 5, 3, 8, 8, 8, 8, 8, 8]
    done, _ = sched.run(_replay_reqs(n, lengths, group_size=3))
    units, groups = group_requests(done)
    for g in groups:
        steps = {r.admitted_step for r in g.requests}
        assert len(steps) == 1, f"group {g.group_id} split: {steps}"
    # distinct slots while co-resident
    for a, b in itertools.combinations(done, 2):
        if a.slot == b.slot:
            assert (a.completed_step <= b.admitted_step
                    or b.completed_step <= a.admitted_step)


def test_intra_gang_prompt_sharing(small_model):
    """Siblings share the leader's freshly-reserved full prompt pages by
    refcount (the group is its own prefix donor on a cold registry)."""
    model, params = small_model
    pc, theta = _probe(0.0, d=model.cfg.d_model)
    cfg = ServeConfig(tokens_per_step=2, max_new_tokens=8, lam=2.0,
                      burn_in=0)
    prompt = jax.random.randint(jax.random.PRNGKey(3), (8,), 0,
                                model.cfg.vocab_size)
    sched = OrcaScheduler(model, params, pc, theta, cfg, n_slots=3,
                          paged=True, block_size=4)
    done, fleet = sched.run(make_group(prompt, 3, group_id=0))
    leader, *sibs = sorted(done, key=lambda r: r.sample_idx)
    assert not leader.prefill_skipped and leader.n_shared_blocks == 0
    for s in sibs:
        assert s.prefill_skipped and s.n_shared_blocks == 2   # 8 tok / bs 4
        # the shared prompt means shared K/V: identical decode streams
        assert s.tokens == leader.tokens
    assert fleet.prefill_skips == 2
    assert sched.pool.num_free == sched.pool.num_usable
    sched.pool.check()


# ---------------------------------------------------------------------------
# consensus stop + mid-flight cancellation

def _consensus_fleet(n_groups=3, group_size=3, t=10, *, lam_sample=2.0,
                     consensus=None, paged=True, chunk_tokens=None,
                     prompt_len=1, n_slots=4, burn_in=2, extra_solo=0):
    n = n_groups * group_size
    bank = _bank(n + extra_solo, t, seed=6)
    # every sample of a group votes its group id: unanimous consensus
    answers = np.repeat(np.arange(n_groups), group_size)
    if extra_solo:
        answers = np.concatenate([answers, np.zeros(extra_solo, np.int64)])
    model = replay_model(bank, prompt_len=prompt_len, answers=answers)
    params = replay_params(bank, answers=answers)
    pc, theta = _probe(1.5, smooth_window=2)
    cfg = ServeConfig(tokens_per_step=1, max_new_tokens=t, lam=lam_sample,
                      burn_in=burn_in)
    sched = OrcaScheduler(model, params, pc, theta, cfg, n_slots=n_slots,
                          paged=paged, block_size=4,
                          chunk_tokens=chunk_tokens, consensus=consensus)
    reqs = _replay_reqs(n, [t] * n, group_size=group_size,
                        prompt_len=prompt_len)
    for i in range(extra_solo):
        reqs.append(make_request(np.full((prompt_len,), n + i, np.int64),
                                 max_new_tokens=t))
    return sched, reqs


def test_consensus_cancels_siblings_and_frees_pages():
    sched, reqs = _consensus_fleet(consensus=0.8)
    done, fleet = sched.run(reqs)
    for g in sched.groups:
        assert g.decided and g.consensus_answer == g.group_id
        assert g.consensus_index == 2          # fires right after burn-in
        assert g.consensus_agreement == pytest.approx(1.0)
        for r in g.requests:
            assert r.state is RequestState.CANCELLED and r.done
            assert r.stop_step == -1
            assert r.completed_step == g.consensus_step
            assert len(r.scores) == 3          # unspent budget returned
    assert fleet.samples_cancelled == 9
    assert fleet.consensus_groups == 3
    assert fleet.consensus_steps == pytest.approx(2.0)
    assert fleet.cancel_freed_blocks > 0
    # group savings COUNT the cancelled samples' unspent budget:
    # group_savings is the TOTAL unspent reasoning steps the fleet got back
    # (3 groups x (3 samples x 10 budget - 9 spent) = 63); the per-group
    # mean fraction lives in group_savings_mean
    assert fleet.group_savings == pytest.approx(3 * (3 * 10 - 9))
    assert fleet.group_savings_mean == pytest.approx(1.0 - 3 / 10)
    assert sched.pool.num_free == sched.pool.num_usable
    sched.pool.check()


def test_consensus_off_groups_run_to_their_own_stops():
    sched, reqs = _consensus_fleet(consensus=None)
    done, fleet = sched.run(reqs)
    assert fleet.samples_cancelled == 0 and fleet.consensus_groups == 0
    assert all(r.state is RequestState.FINISHED for r in done)
    assert sched.pool.num_free == sched.pool.num_usable


def test_cancelled_samples_excluded_from_latency_tails():
    sched, reqs = _consensus_fleet(consensus=0.8, extra_solo=2)
    done, fleet = sched.run(reqs)
    kept = [r for r in done if r.state is not RequestState.CANCELLED]
    assert len(kept) == 2
    ttft = np.array([r.ttft_s for r in kept if r.ttft_s >= 0]) * 1e3
    assert fleet.ttft_ms_p50 == pytest.approx(float(np.percentile(ttft, 50)))
    assert fleet.ttft_ms_p99 == pytest.approx(float(np.percentile(ttft, 99)))


def test_cancel_mid_prefill_leaves_pool_and_slot_clean():
    """Chunked prefill staggers the gang (sample spreading): the consensus
    fires while the LAST sibling is still mid-prefill — cancelling it must
    drop the parked row, its deferred donor plan and its pages without it
    ever decoding a token."""
    sched, reqs = _consensus_fleet(consensus=GroupCalibrator(
        min_votes=2, burn_in=0, lam=0.5), n_groups=1, prompt_len=24,
        chunk_tokens=4, burn_in=0, extra_solo=1)
    done, fleet = sched.run(reqs)
    grp = sched.groups[0]
    assert grp.decided
    last = max(grp.requests, key=lambda r: r.sample_idx)
    assert last.state is RequestState.CANCELLED
    assert last.prefill_progress < last.prompt_len   # cancelled MID-prefill
    assert len(last.tokens) == 0
    assert fleet.cancel_freed_blocks > 0
    # the freed slot and pages are genuinely reusable: the solo request
    # admitted after the gang still runs to completion
    solo = done[-1]
    assert solo.group_id is None
    assert solo.state is RequestState.FINISHED and len(solo.tokens) == 10
    assert sched.pool.num_free == sched.pool.num_usable
    sched.pool.check()
    # the cancelled slot's engine row is parked (frozen no-op compute)
    assert bool(sched._engine.st.stopped[last.slot])


# ---------------------------------------------------------------------------
# served == offline: the consensus decision sequence is the calibrated one

def test_served_consensus_matches_offline_trace():
    """The scheduler's per-step decide() replays ``consensus_trace`` +
    ``consensus_stop_times`` bit-for-bit: same fire index, same answer —
    including groups that never fire and samples frozen by budget."""
    n_groups, gs, t = 4, 3, 12
    n = n_groups * gs
    bank = _bank(n, t, seed=12)
    # mixed agreement: groups 0/2 unanimous, group 1 split 2-1, group 3
    # fully split (can never clear a 0.6 threshold)
    answers = np.repeat(np.arange(n_groups), gs)
    answers[5] = 90
    answers[9:12] = [91, 92, 93]
    lengths = np.array([12, 9, 12, 12, 12, 7, 10, 12, 12, 12, 12, 12])
    model = replay_model(bank, answers=answers)
    params = replay_params(bank, answers=answers)
    pc, theta = _probe(0.8, smooth_window=2)
    lam_g, burn = 0.6, 2
    cfg = ServeConfig(tokens_per_step=1, max_new_tokens=t, lam=2.0,
                      burn_in=burn)
    # offline scores: the ungrouped serve of the same fleet (per-slot score
    # invariance makes these THE deployed-procedure scores)
    ref = OrcaScheduler(model, params, pc, theta, cfg, n_slots=4,
                        paged=True, block_size=4)
    base, _ = ref.run(_replay_reqs(n, lengths))
    sc = np.zeros((n, t))
    for i, r in enumerate(base):
        sc[i, :len(r.scores)] = r.scores
    an = np.repeat(answers[:, None], t, axis=1)

    sched = OrcaScheduler(model, params, pc, theta, cfg, n_slots=4,
                          paged=True, block_size=4,
                          consensus=GroupCalibrator(min_votes=2,
                                                    burn_in=burn,
                                                    lam=lam_g))
    done, fleet = sched.run(_replay_reqs(n, lengths, group_size=gs))
    fired = 0
    for g in sched.groups:
        rows = slice(g.group_id * gs, (g.group_id + 1) * gs)
        ans_t, agr_t = S.consensus_trace(sc[rows], an[rows], lengths[rows])
        tau = int(S.consensus_stop_times(agr_t, [lam_g], burn_in=burn)[0])
        if tau < int(lengths[rows].max()):
            assert g.decided and g.consensus_index == tau
            assert g.consensus_answer == int(ans_t[tau])
            fired += 1
        else:
            assert not g.decided
    assert 0 < fired < n_groups          # both outcomes exercised
    assert sched.pool.num_free == sched.pool.num_usable


# ---------------------------------------------------------------------------
# cancellation fuzz: group_size x budgets x policy x paged/dense

def _fuzz_round(group_size, n_slots, policy, paged, consensus_on, seed):
    n, t = 12 - (12 % max(group_size, 1)), 10
    bank = _bank(n, t, seed=seed)
    answers = (np.arange(n) // group_size if group_size else None)
    rs = np.random.RandomState(seed)
    lengths = rs.choice([6, 8, 10], size=n)
    model = replay_model(bank, answers=answers)
    params = replay_params(bank, answers=answers)
    pc, theta = _probe(1.2, smooth_window=2)
    cfg = ServeConfig(tokens_per_step=1, max_new_tokens=t, lam=0.65,
                      burn_in=1)
    consensus = 0.8 if (consensus_on and group_size >= 2) else None
    sched = OrcaScheduler(model, params, pc, theta, cfg, n_slots=n_slots,
                          paged=paged, block_size=4, policy=policy,
                          consensus=consensus)
    reqs = _replay_reqs(n, lengths, group_size=group_size or None)
    for i, r in enumerate(reqs):
        r.priority = i % 2
    done, fleet = sched.run(reqs)
    # every request terminal; cancelled ones only from decided groups
    assert all(r.done for r in done)
    for g in sched.groups:
        if g.n_cancelled:
            assert g.decided
        steps = {r.admitted_step for r in g.requests}
        assert len(steps) == 1                    # gang stayed atomic
    # no double slot occupancy across overlapping lifetimes — a preempted
    # request vacates its slot while SWAPPED, so its last residency starts
    # at restored_step, not admitted_step (step-level double ownership is
    # owned by tests/test_preemption.py + pool.check)
    def _resident_from(r):
        return r.restored_step if r.n_preempted else r.admitted_step
    for a, b in itertools.combinations(done, 2):
        if a.slot == b.slot:
            assert (a.completed_step <= _resident_from(b)
                    or b.completed_step <= _resident_from(a))
    if paged:
        # every page came home: refcounts hit 0, nothing leaked or doubled
        assert sched.pool.num_free == sched.pool.num_usable
        assert fleet.peak_blocks_in_use <= sched.pool.num_usable
        sched.pool.check()
    return done


@pytest.mark.parametrize("policy", ["fifo", "priority", "ttft"])
@pytest.mark.parametrize("group_size,paged", [(1, True), (2, False),
                                              (3, True), (4, True)])
def test_cancellation_invariants_pinned(policy, group_size, paged):
    _fuzz_round(group_size, max(4, group_size), policy, paged,
                consensus_on=True, seed=group_size)


@settings(max_examples=12, deadline=None)
@given(group_size=st.integers(1, 4), slot_pad=st.integers(0, 2),
       policy=st.sampled_from(["fifo", "priority", "ttft"]),
       paged=st.booleans(), consensus_on=st.booleans(),
       seed=st.integers(0, 5))
def test_cancellation_fuzz(group_size, slot_pad, policy, paged,
                           consensus_on, seed):
    done = _fuzz_round(group_size, group_size + slot_pad + 1, policy, paged,
                       consensus_on, seed)
    if group_size == 1 or not consensus_on:
        # inert layer: bit-equal to the ungrouped oracle
        oracle = _fuzz_round(0, group_size + slot_pad + 1, policy, paged,
                             consensus_on=False, seed=seed)
        assert [r.stop_step for r in done] == [r.stop_step for r in oracle]


# ---------------------------------------------------------------------------
# api facade end-to-end

def test_api_serve_requests_expands_groups():
    ts = generate(TrajectoryDistribution("facade", d_phi=D, t_min=8,
                                         t_max=12), 30, seed=2)
    calib = orca.fit(ts.subset(np.arange(15)), mode="consistent",
                     method="static", n_components=8, smooth_window=2,
                     epochs=40)
    fleet_ts = make_group_fleet(ts.subset(np.arange(15, 30)), 3, seed=0)
    sched = orca.engine(fleet_ts.model, fleet_ts.params, calib, n_slots=4,
                        lam=2.0, tokens_per_step=1, max_new_tokens=10,
                        group_size=3, consensus=0.8)
    prompts = np.stack([np.asarray(r.inputs["tokens"][0])
                        for r in fleet_ts.requests[::3]])
    done, fleet = orca.serve_requests(sched, prompts)
    assert len(done) == 3 * len(prompts)
    assert {r.group_id for r in done} == set(range(len(prompts)))
    assert all(r.done for r in done)
